"""``wave_fused`` -- one FUSED persistence wave over the two live ring rows.

The wave engine's hot path (DESIGN.md §3b) touches exactly two rows of the
[S, R] segment pool per wave: ``last`` (enqueue side, L) and ``first``
(dequeue side, F).  This kernel runs the whole per-wave pipeline against
those rows while they sit in VMEM:

  1. W enqueue transitions on the L row (Algorithm 3 line 14),
  2. W dequeue / empty / unsafe transitions on the F row (lines 34/38/41),
     reading the post-enqueue cells when L == F,
  3. the NVM cell flush of ONLY the touched slots (the pwb analog) for both
     rows -- the durable image rows ride along in the same VMEM residency.

Semantically the flush is an ORDERED pwb sequence (enqueue cells in ticket
order, then dequeue cells, then mirror + header lines) drained by the
wave-end psync -- NOT an atomic image overwrite.  This kernel computes the
all-records-landed endpoint of that sequence; ``core/wave.wave_step_delta``
exposes the sequence itself as a ``persistence.WaveDelta`` (bit-identical
when fully applied -- the parity tests assert it), which the torn-crash
injector cuts at arbitrary prefix+eviction points (DESIGN.md §7).  The
trailing mirror and segment-header records (closed bits + allocation
epochs + recycling bases -- the epoch-ordered list word of DESIGN.md §3c)
are tiny [P]/[S] metadata lines flushed by ``_wave_step`` itself, shared
verbatim across backends: the kernel stays a pure cell pipeline, and a
recycled row's stale cells need no in-kernel scrubbing because every
pre-incarnation index sits below the row's persisted base.

The caller (core/wave.py ``_wave_step``) dynamic-slices the rows out of the
[S, R] pool and writes the results back with one dynamic-update-slice per
array -- so a wave costs two row round-trips instead of the chain of
full-array scatters the unfused path paid.

``same_seg`` is the traced L == F predicate.  The kernel preserves the
aliasing by seeding the F pass from the post-enqueue L rows and folding the
F results back into the L outputs, so the returned L and F rows are equal
whenever the segments alias (the write-back order then does not matter).

Tickets are pairwise distinct within a wave (fai_ticket), so the sequential
fori_loop over lanes is conflict-free; W is the small axis, R the large one.
VMEM budget: 12 int32 rows of R + 7 wave arrays of W -- R=8192, W=512 =>
~400KB, comfortably inside a TPU core's ~16MB VMEM.  Interpret mode keeps
the same program runnable on CPU CI.

Scope: this kernel is ONE queue's wave.  The fabric used to scale over
shards by vmapping it Q times per driver round; backends that grant the
``fused_fabric_round`` capability now run the whole Q-shard round as a
single gridded program instead (kernels/fabric_fused.py, DESIGN.md §3d),
and this per-wave kernel remains the single-queue / vmapped-fallback path
the megakernel is held bit-identical to.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

BOT = -1
EMPTY_V = -2
RETRY_V = -3
IDLE_V = -4


def _wave_fused_kernel(
    head_ref, same_ref,                                  # SMEM (1,) each
    vL_ref, iL_ref, sL_ref, vF_ref, iF_ref, sF_ref,      # [R] VMEM vol rows
    nvL_ref, niL_ref, nsL_ref, nvF_ref, niF_ref, nsF_ref,  # [R] VMEM nvm rows
    et_ref, ev_ref, ea_ref, dt_ref, da_ref,              # [W] VMEM wave
    ovL_ref, oiL_ref, osL_ref, ovF_ref, oiF_ref, osF_ref,      # [R] outputs
    onvL_ref, oniL_ref, onsL_ref, onvF_ref, oniF_ref, onsF_ref,  # [R] outputs
    eok_ref, dout_ref,                                   # [W] outputs
    *,
    do_enq: bool, do_deq: bool,
):
    R = vL_ref.shape[0]
    W = et_ref.shape[0]
    head = head_ref[0]
    same = same_ref[0] != 0

    # ---- 1. enqueue transitions on the L row -----------------------------
    ovL_ref[...] = vL_ref[...]
    oiL_ref[...] = iL_ref[...]
    osL_ref[...] = sL_ref[...]

    def enq_body(i, _):
        t = et_ref[i]
        active = ea_ref[i] != 0
        slot = t % R
        ci = oiL_ref[slot]
        cv = ovL_ref[slot]
        cs = osL_ref[slot]
        ok = active & (ci <= t) & (cv == BOT) & ((cs == 1) | (head <= t))
        ovL_ref[slot] = jnp.where(ok, ev_ref[i], cv)
        oiL_ref[slot] = jnp.where(ok, t, ci)
        osL_ref[slot] = jnp.where(ok, 1, cs)
        eok_ref[i] = ok.astype(jnp.int32)
        return 0

    if do_enq:
        jax.lax.fori_loop(0, W, enq_body, 0)
    else:
        eok_ref[...] = jnp.zeros((W,), jnp.int32)

    # ---- 2. dequeue transitions on the F row (post-enqueue when L == F) --
    ovF_ref[...] = jnp.where(same, ovL_ref[...], vF_ref[...])
    oiF_ref[...] = jnp.where(same, oiL_ref[...], iF_ref[...])
    osF_ref[...] = jnp.where(same, osL_ref[...], sF_ref[...])

    def deq_body(i, _):
        t = dt_ref[i]
        active = da_ref[i] != 0
        slot = t % R
        ci = oiF_ref[slot]
        cv = ovF_ref[slot]
        cs = osF_ref[slot]
        occupied = cv != BOT
        deq_tr = active & occupied & (ci == t)
        empty_tr = active & (~occupied) & (ci <= t)
        unsafe_tr = active & occupied & (ci < t)
        out = jnp.where(
            deq_tr, cv,
            jnp.where(empty_tr, jnp.int32(EMPTY_V),
                      jnp.where(active, jnp.int32(RETRY_V),
                                jnp.int32(IDLE_V))))
        adv = deq_tr | empty_tr
        ovF_ref[slot] = jnp.where(adv, BOT, cv)
        oiF_ref[slot] = jnp.where(adv, t + R, ci)
        osF_ref[slot] = jnp.where(unsafe_tr, 0, cs)
        dout_ref[i] = out
        return 0

    if do_deq:
        jax.lax.fori_loop(0, W, deq_body, 0)
        # fold the dequeue results back into L when the segments alias
        ovL_ref[...] = jnp.where(same, ovF_ref[...], ovL_ref[...])
        oiL_ref[...] = jnp.where(same, oiF_ref[...], oiL_ref[...])
        osL_ref[...] = jnp.where(same, osF_ref[...], osL_ref[...])
    else:
        dout_ref[...] = jnp.full((W,), IDLE_V, jnp.int32)

    # ---- 3. NVM cell flush: only the touched slots (the pwb analog) ------
    onvL_ref[...] = nvL_ref[...]
    oniL_ref[...] = niL_ref[...]
    onsL_ref[...] = nsL_ref[...]

    def flush_enq_body(i, _):
        ok = eok_ref[i] != 0
        slot = et_ref[i] % R
        onvL_ref[slot] = jnp.where(ok, ovL_ref[slot], onvL_ref[slot])
        oniL_ref[slot] = jnp.where(ok, oiL_ref[slot], oniL_ref[slot])
        onsL_ref[slot] = jnp.where(ok, osL_ref[slot], onsL_ref[slot])
        return 0

    if do_enq:
        jax.lax.fori_loop(0, W, flush_enq_body, 0)

    onvF_ref[...] = jnp.where(same, onvL_ref[...], nvF_ref[...])
    oniF_ref[...] = jnp.where(same, oniL_ref[...], niF_ref[...])
    onsF_ref[...] = jnp.where(same, onsL_ref[...], nsF_ref[...])

    def flush_deq_body(i, _):
        touched = dout_ref[i] != IDLE_V
        slot = dt_ref[i] % R
        onvF_ref[slot] = jnp.where(touched, ovF_ref[slot], onvF_ref[slot])
        oniF_ref[slot] = jnp.where(touched, oiF_ref[slot], oniF_ref[slot])
        onsF_ref[slot] = jnp.where(touched, osF_ref[slot], onsF_ref[slot])
        return 0

    if do_deq:
        jax.lax.fori_loop(0, W, flush_deq_body, 0)
        onvL_ref[...] = jnp.where(same, onvF_ref[...], onvL_ref[...])
        oniL_ref[...] = jnp.where(same, oniF_ref[...], oniL_ref[...])
        onsL_ref[...] = jnp.where(same, onsF_ref[...], onsL_ref[...])


@functools.partial(jax.jit, static_argnames=("interpret", "do_enq",
                                             "do_deq"))
def wave_fused(
    vals_L, idxs_L, safes_L, vals_F, idxs_F, safes_F,
    nvals_L, nidxs_L, nsafes_L, nvals_F, nidxs_F, nsafes_F,
    head_L, same_seg,
    enq_tickets, enq_vals, enq_active,
    deq_tickets, deq_active,
    *,
    interpret: bool = True,
    do_enq: bool = True,
    do_deq: bool = True,
):
    R = vals_L.shape[0]
    W = enq_tickets.shape[0]
    row = pl.BlockSpec((R,), lambda: (0,))
    wav = pl.BlockSpec((W,), lambda: (0,))
    smem = pl.BlockSpec(memory_space=pltpu.SMEM)
    r_out = jax.ShapeDtypeStruct((R,), jnp.int32)
    w_out = jax.ShapeDtypeStruct((W,), jnp.int32)
    outs = pl.pallas_call(
        functools.partial(_wave_fused_kernel, do_enq=do_enq, do_deq=do_deq),
        in_specs=[smem, smem] + [row] * 12 + [wav] * 5,
        out_specs=[row] * 12 + [wav] * 2,
        out_shape=[r_out] * 12 + [w_out] * 2,
        interpret=interpret,
    )(
        jnp.asarray(head_L, jnp.int32).reshape(1),
        jnp.asarray(same_seg, jnp.int32).reshape(1),
        jnp.asarray(vals_L, jnp.int32),
        jnp.asarray(idxs_L, jnp.int32),
        jnp.asarray(safes_L, jnp.int32),
        jnp.asarray(vals_F, jnp.int32),
        jnp.asarray(idxs_F, jnp.int32),
        jnp.asarray(safes_F, jnp.int32),
        jnp.asarray(nvals_L, jnp.int32),
        jnp.asarray(nidxs_L, jnp.int32),
        jnp.asarray(nsafes_L, jnp.int32),
        jnp.asarray(nvals_F, jnp.int32),
        jnp.asarray(nidxs_F, jnp.int32),
        jnp.asarray(nsafes_F, jnp.int32),
        jnp.asarray(enq_tickets, jnp.int32),
        jnp.asarray(enq_vals, jnp.int32),
        jnp.asarray(enq_active, jnp.int32),
        jnp.asarray(deq_tickets, jnp.int32),
        jnp.asarray(deq_active, jnp.int32),
    )
    return tuple(outs)
