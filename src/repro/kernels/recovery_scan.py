"""``recovery_scan`` -- the paper's recovery scans as TPU reduction kernels.

Two kernels:

* ``percrq_recovery_scan``: Algorithm 3 lines 61-80 for one ring segment --
  five masked reductions (max occupied idx+1, max advanced-empty idx-R+1,
  in-range max/min passes) fused into one VMEM pass over the blocked ring.
  The cross-pass data dependence (head1 depends on tail1, ...) is resolved by
  computing ALL candidate reductions blockwise and combining the carries at
  the end -- one HBM read of the segment instead of four.

* ``periq_streak``: Algorithm 1 lines 19-23 -- find the first run of n
  consecutive ⊥ cells.  Blocked scan carrying (current streak, found index)
  in SMEM across sequential grid steps.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

BOT = -1
_NEG = -(2**30)  # python ints: inlined as literals (no captured constants)
_POS = 2**30


def _percrq_scan_kernel(
    head0_ref,                      # SMEM (1,)
    vals_ref, idxs_ref,             # [blk] VMEM
    out_ref,                        # SMEM (2,): head, tail
    acc_ref,                        # SMEM (4,): t_occ, t_emp, mx, mn
):
    """Three sequential sweeps over the blocked ring (grid = 3 * n_blocks):
    sweep 0 accumulates the Tail candidates (lines 61-68), sweep 1 the
    in-range empty-cell maximum (lines 71-75, needs Tail), sweep 2 the
    in-range occupied minimum (lines 76-80, needs the updated Head).  Carries
    live in SMEM; grid steps execute in order on TPU."""
    i = pl.program_id(0)
    nb = pl.num_programs(0)
    n_blocks = nb // 3
    blk = vals_ref.shape[0]
    R_total = n_blocks * blk
    head0 = head0_ref[0]

    @pl.when(i == 0)
    def _init():
        acc_ref[0] = 0        # max(occupied idx + 1)
        acc_ref[1] = 0        # max(empty advanced idx - R + 1)
        acc_ref[2] = _NEG     # max in-range empty (idx - R + 1)
        acc_ref[3] = _POS     # min in-range occupied >= head1

    vals = vals_ref[...]
    idxs = idxs_ref[...]
    occupied = vals != BOT
    phase = i // n_blocks
    blk_i = i % n_blocks
    u = blk_i * blk + jax.lax.iota(jnp.int32, blk)

    @pl.when(phase == 0)
    def _tail_pass():
        t_occ = jnp.max(jnp.where(occupied, idxs + 1, 0))
        t_emp = jnp.max(jnp.where((~occupied) & (idxs >= R_total),
                                  idxs - R_total + 1, 0))
        acc_ref[0] = jnp.maximum(acc_ref[0], t_occ)
        acc_ref[1] = jnp.maximum(acc_ref[1], t_emp)

    tail0 = jnp.maximum(acc_ref[0], acc_ref[1])
    tail1 = jnp.where(head0 > tail0, head0, tail0)

    @pl.when(phase == 1)
    def _mx_pass():
        live = jnp.minimum(jnp.maximum(tail1 - head0, 0), R_total)
        in_range = ((u - head0) % R_total) < live
        mx = jnp.max(jnp.where(in_range & (~occupied),
                               idxs - R_total + 1, _NEG))
        acc_ref[2] = jnp.maximum(acc_ref[2], mx)

    @pl.when(phase == 2)
    def _mn_pass():
        head1 = jnp.maximum(head0, acc_ref[2])
        live2 = jnp.minimum(jnp.maximum(tail1 - head1, 0), R_total)
        in_range2 = ((u - head1) % R_total) < live2
        mn = jnp.min(jnp.where(in_range2 & occupied & (idxs >= head1),
                               idxs, _POS))
        acc_ref[3] = jnp.minimum(acc_ref[3], mn)

        @pl.when(i == nb - 1)
        def _fini():
            head1_f = jnp.maximum(head0, acc_ref[2])
            mn_all = acc_ref[3]
            head2 = jnp.where(head0 > tail0, head0,
                              jnp.where(mn_all < tail1, mn_all, head1_f))
            tail2 = jnp.where(head0 > tail0, head0, tail1)
            out_ref[0] = head2
            out_ref[1] = tail2


@functools.partial(jax.jit, static_argnames=("block", "interpret"))
def percrq_recovery_scan(vals, idxs, head0, *, block: int = 2048, interpret: bool = True):
    """Returns (head, tail) recovered for one segment."""
    R = vals.shape[0]
    blk = min(block, R)
    assert R % blk == 0, (R, blk)
    n_blocks = R // blk
    out, _acc = pl.pallas_call(
        _percrq_scan_kernel,
        grid=(3 * n_blocks,),
        in_specs=[
            pl.BlockSpec(memory_space=pltpu.SMEM),
            pl.BlockSpec((blk,), lambda i, n=n_blocks: (i % n,)),
            pl.BlockSpec((blk,), lambda i, n=n_blocks: (i % n,)),
        ],
        out_specs=[
            pl.BlockSpec(memory_space=pltpu.SMEM),
            pl.BlockSpec(memory_space=pltpu.SMEM),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((2,), jnp.int32),
            jax.ShapeDtypeStruct((4,), jnp.int32),
        ],
        interpret=interpret,
    )(
        jnp.asarray(head0, jnp.int32).reshape(1),
        jnp.asarray(vals, jnp.int32),
        jnp.asarray(idxs, jnp.int32),
    )
    return out[0], out[1]


# ---------------------------------------------------------------------------
# PerIQ streak scan
# ---------------------------------------------------------------------------


def _periq_streak_kernel(n_ref, vals_ref, out_ref, carry_ref):
    """carry = (running streak length, found start or BIG)."""
    i = pl.program_id(0)
    nb = pl.num_programs(0)
    blk = vals_ref.shape[0]
    n = n_ref[0]

    @pl.when(i == 0)
    def _init():
        carry_ref[0] = 0      # streak entering this block
        carry_ref[1] = _POS   # first found start index

    vals = vals_ref[...]
    is_bot = (vals == BOT).astype(jnp.int32)

    def body(j, state):
        streak, found = state
        streak = jnp.where(is_bot[j] == 1, streak + 1, 0)
        pos = i * blk + j
        hit = (streak >= n) & (found == _POS)
        found = jnp.where(hit, pos - n + 1, found)
        return streak, found

    streak, found = jax.lax.fori_loop(0, blk, body, (carry_ref[0], carry_ref[1]))
    carry_ref[0] = streak
    carry_ref[1] = found

    @pl.when(i == nb - 1)
    def _fini():
        out_ref[0] = jnp.where(carry_ref[1] == _POS,
                               jnp.int32(nb * blk), carry_ref[1])


@functools.partial(jax.jit, static_argnames=("block", "interpret"))
def periq_streak(vals, n, *, block: int = 2048, interpret: bool = True):
    """Index of the first cell of the first run of n consecutive ⊥ values."""
    N = vals.shape[0]
    blk = min(block, N)
    pad = (-N) % blk
    vals_p = jnp.pad(jnp.asarray(vals, jnp.int32), (0, pad), constant_values=0)
    n_blocks = vals_p.shape[0] // blk
    out = pl.pallas_call(
        _periq_streak_kernel,
        grid=(n_blocks,),
        in_specs=[
            pl.BlockSpec(memory_space=pltpu.SMEM),
            pl.BlockSpec((blk,), lambda i: (i,)),
        ],
        out_specs=pl.BlockSpec(memory_space=pltpu.SMEM),
        out_shape=jax.ShapeDtypeStruct((1,), jnp.int32),
        scratch_shapes=[pltpu.SMEM((2,), jnp.int32)],
        interpret=interpret,
    )(jnp.asarray(n, jnp.int32).reshape(1), vals_p)
    return jnp.minimum(out[0], N)
