"""``fabric_fused_round`` -- the fused-fabric MEGAKERNEL (DESIGN.md §3d).

One whole driver round over ALL Q shards as a single Pallas program.  The
per-wave kernel (``wave_fused.py``) fused the cell pipeline of ONE queue's
wave; the driver loops still dispatched it Q times per round under a
``vmap`` -- per-wave kernel dispatch overhead grew with Q instead of
amortizing, which is exactly the serial bottleneck BlockFIFO-style sharding
is supposed to remove.  This kernel grids the round over the shard axis
instead: grid program g owns a block of ``q_block`` consecutive shards and
executes their ENTIRE round -- lane selection (``_select_rows`` /
``_plan_round``), the W enqueue + W dequeue transitions on the two live
rows, segment advance/recycle progress, and the fused NVM cell flush --
against per-shard blocks dynamically sliced out of the Q-stacked [Q, S, R]
pool, so a driver round costs ONE kernel launch however many shards run.

``q_block`` picks the grid decomposition: 1 on a real TPU (one shard per
grid program, programs run on parallel cores / pipeline over the grid), Q
in interpret mode (grid programs serialize on CPU, so the block axis is
vmapped inside the body and the host vector units do the shard
parallelism).  Both decompositions run the SAME body and are parity-tested
against each other and against the vmapped per-wave path.

The body reconstructs the block's WaveState VALUES from the refs and runs
the exact functional round code of ``core/wave._wave_step`` (with the jnp
value-level backend) + ``core/driver``'s selection/planning helpers --
bit-identical to the vmapped fallback by construction, so ``WaveDelta``
emission, persist accounting, recycling epochs/bases and
``check_wave_crash`` semantics are untouched.  Three STATIC phases mirror
the three dispatch sites:

  * ``"enq"``  -- the ``_enqueue_all_impl`` round body: in-kernel selection
                  of the first W remaining items per shard, enqueue-only
                  half-wave (prefix lanes).  Extra outputs (ev, idx, ok) let
                  the driver keep its done-marking + accounting verbatim.
  * ``"deq"``  -- the ``_dequeue_n_impl`` round body: every program
                  replicates the Q-wide work-stealing plan from the full
                  backlog snapshot (tiny [Q, S] reduction; cross-shard by
                  nature) and takes its own shards' lane counts, then runs
                  the dequeue-only half-wave.  Extra outputs (outw, counts,
                  probe) feed the driver's compaction + accounting.
  * ``"wave"`` -- the general ``fabric_step`` body: one full fused wave
                  (enq + deq, arbitrary lane masks) per shard.

SMEM holds the cross-program scalars (consumer shard, remaining demand,
rotation cursor); everything per-shard rides in VMEM blocks.  VMEM budget
per grid program: q_block * (6 int32 [S, R] pool blocks + the [S]/[P]
metadata + 7 wave arrays of W) -- at q_block=1, S=8, R=8192, W=512 that is
6*8*8192*4B ~= 1.5MB + ~15KB, comfortably inside a TPU core's ~16MB VMEM
(the per-wave kernel's 12-rows-of-R budget bounded the same pool from
below; the megakernel trades S/2 extra resident rows for zero per-wave
dispatch).  Interpret mode keeps the same program runnable on CPU CI.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.backend import (BOT, IDLE_V, JnpBackend, _deq_predicates,
                                _enq_predicate)
from repro.core.driver import _plan_round, _select_rows
from repro.core.wave import WaveState, _wave_step


class _SlotWindowBackend(JnpBackend):
    """JnpBackend whose prefix HALF-waves run in SLOT space.

    The roll+window formulation (``JnpBackend._fused_wave_prefix``) moves
    every live row through two R-length rolls per array -- 12 full-row
    gathers per half-wave.  Under the megakernel's in-body vmap over the
    shard block those rolls become batched gathers with per-shard traced
    shifts, which the CPU scalarizes: per-round cost grew ~3x from Q=1 to
    Q=4 and ate the round-count win.  This formulation flips the mapping:
    instead of rolling the rows into lane space, evaluate the transition
    predicates at every ring SLOT -- for a prefix-active wave the inverse
    map is affine (``lane_of_slot = (slot - base) % R``, ticket ``base +
    lane_of_slot``), so the cell updates and the NVM flush become pure
    elementwise selects on the un-rolled rows, plus ONE W-from-R gather for
    the input values and ONE R-from-W gather back to lane order for the
    outputs.  Same predicates (``_enq_predicate`` / ``_deq_predicates``),
    same cells touched, bit-identical results -- the megakernel parity
    tests hold it to the vmapped roll path on both backends.

    Only the enqueue-only / dequeue-only prefix waves (the driver rounds,
    i.e. everything the megakernel dispatches) take this path; full waves
    and arbitrary lane masks fall back to the general formulation."""

    name = "jnp-slotwin"

    def fused_wave(self, vals_L, idxs_L, safes_L, vals_F, idxs_F, safes_F,
                   nvals_L, nidxs_L, nsafes_L, nvals_F, nidxs_F, nsafes_F,
                   head_L, same_seg,
                   enq_tickets, enq_vals, enq_active,
                   deq_tickets, deq_active,
                   do_enq: bool = True, do_deq: bool = True,
                   prefix_lanes: bool = False):
        if not prefix_lanes or (do_enq and do_deq):
            return super().fused_wave(
                vals_L, idxs_L, safes_L, vals_F, idxs_F, safes_F,
                nvals_L, nidxs_L, nsafes_L, nvals_F, nidxs_F, nsafes_F,
                head_L, same_seg, enq_tickets, enq_vals, enq_active,
                deq_tickets, deq_active, do_enq=do_enq, do_deq=do_deq,
                prefix_lanes=prefix_lanes)
        R = vals_L.shape[0]
        W = enq_tickets.shape[0]
        u = jnp.arange(R, dtype=jnp.int32)
        w = jnp.arange(W, dtype=jnp.int32)
        if do_enq:
            be = enq_tickets[0]          # lane 0's ticket == the Tail base
            lane_of_slot = (u - be) % R  # affine inverse of slot = t % R
            in_win = lane_of_slot < W
            t_slot = be + lane_of_slot
            k = jnp.sum(enq_active.astype(jnp.int32))  # active lanes 0..k-1
            act = in_win & (lane_of_slot < k)
            ok_s = _enq_predicate(vals_L, idxs_L, safes_L, t_slot, act,
                                  head_L)
            ev_s = enq_vals[jnp.where(in_win, lane_of_slot, 0)]
            vals2 = jnp.where(ok_s, ev_s, vals_L)
            idxs2 = jnp.where(ok_s, t_slot, idxs_L)
            safes2 = jnp.where(ok_s, True, safes_L)
            enq_ok = ok_s[(be + w) % R]
            # flush exactly the touched cells (the pwb analog)
            return (vals2, idxs2, safes2, vals_F, idxs_F, safes_F,
                    jnp.where(ok_s, vals2, nvals_L),
                    jnp.where(ok_s, idxs2, nidxs_L),
                    jnp.where(ok_s, safes2, nsafes_L),
                    nvals_F, nidxs_F, nsafes_F,
                    enq_ok, jnp.full((W,), IDLE_V, jnp.int32))
        # dequeue-only half-wave (same_seg needs no seeding: when L == F the
        # caller passed the SAME row as both inputs, and do_enq is False so
        # the L image is untouched; fold the F results back into L exactly
        # like the roll path's early return)
        bd = deq_tickets[0]              # lane 0's ticket == the Head base
        lane_of_slot = (u - bd) % R
        in_win = lane_of_slot < W
        t_slot = bd + lane_of_slot
        k = jnp.sum(deq_active.astype(jnp.int32))
        act = in_win & (lane_of_slot < k)
        adv_s, unsafe_s, dout_s = _deq_predicates(vals_F, idxs_F, t_slot,
                                                  act)
        vals2 = jnp.where(adv_s, BOT, vals_F)
        idxs2 = jnp.where(adv_s, t_slot + R, idxs_F)
        safes2 = jnp.where(unsafe_s, False, safes_F)
        touched = dout_s != IDLE_V
        nvals2 = jnp.where(touched, vals2, nvals_F)
        nidxs2 = jnp.where(touched, idxs2, nidxs_F)
        nsafes2 = jnp.where(touched, safes2, nsafes_F)
        deq_out = dout_s[(bd + w) % R]
        return (jnp.where(same_seg, vals2, vals_L),
                jnp.where(same_seg, idxs2, idxs_L),
                jnp.where(same_seg, safes2, safes_L),
                vals2, idxs2, safes2,
                jnp.where(same_seg, nvals2, nvals_L),
                jnp.where(same_seg, nidxs2, nidxs_L),
                jnp.where(same_seg, nsafes2, nsafes_L),
                nvals2, nidxs2, nsafes2,
                jnp.zeros((W,), bool), deq_out)


# The value-level backend the kernel body runs on the block's state values;
# identical transitions to the vmapped fallback path (the slot-space prefix
# formulation above is held bit-identical by the parity tests).
_VALUE_BACKEND = _SlotWindowBackend()


def _read_states(refs):
    """Rebuild the block's (vol, nvm) WaveState VALUES from the 18 input
    refs.  The nvm image only ships the leaves ``_wave_step`` reads or
    writes (cells + mirrors); the pass-through metadata is seeded from vol
    and discarded by the wrapper, which reassembles the true nvm output."""
    (vv, vi, vs, vh, vt, vc, vep, vb, vf, vl, vm, vms,
     nv, ni, ns, nm, nms) = refs
    vol = WaveState(
        vals=vv[...], idxs=vi[...], safes=vs[...] != 0,
        heads=vh[...], tails=vt[...], closed=vc[...] != 0,
        epoch=vep[...], base=vb[...], first=vf[...], last=vl[...],
        mirrors=vm[...], mirror_seg=vms[...])
    nvm = WaveState(
        vals=nv[...], idxs=ni[...], safes=ns[...] != 0,
        heads=vol.heads, tails=vol.tails, closed=vol.closed,
        epoch=vol.epoch, base=vol.base, first=vol.first, last=vol.last,
        mirrors=nm[...], mirror_seg=nms[...])
    return vol, nvm


def _write_states(refs, vol, nvm):
    (ovv, ovi, ovs, ovh, ovt, ovc, ovep, ovb, ovf, ovl, ovm, ovms,
     onv, oni, ons, onm, onms) = refs
    i32 = jnp.int32
    ovv[...], ovi[...], ovs[...] = vol.vals, vol.idxs, vol.safes.astype(i32)
    ovh[...], ovt[...], ovc[...] = (vol.heads, vol.tails,
                                    vol.closed.astype(i32))
    ovep[...], ovb[...] = vol.epoch, vol.base
    ovf[...], ovl[...] = vol.first, vol.last
    ovm[...], ovms[...] = vol.mirrors, vol.mirror_seg
    onv[...], oni[...], ons[...] = nvm.vals, nvm.idxs, nvm.safes.astype(i32)
    onm[...], onms[...] = nvm.mirrors, nvm.mirror_seg


def _fabric_round_kernel(*refs, phase: str, W: int, q_block: int):
    b = _VALUE_BACKEND
    shard = refs[0][0]
    state_in, rest = refs[1:18], refs[18:]
    vol, nvm = _read_states(state_in)
    if phase == "enq":
        items_ref, done_ref = rest[0], rest[1]
        state_out, (oev, oidx, ook) = rest[2:19], rest[19:]
        items, done = items_ref[...], done_ref[...] != 0
        ev, idx = jax.vmap(_select_rows, in_axes=(0, 0, None))(items, done, W)
        dm = jnp.zeros((q_block, W), bool)
        vol, nvm, ok, _ = jax.vmap(
            lambda v, m, e, d: _wave_step(v, m, e, d, shard, b,
                                          do_enq=True, do_deq=False,
                                          prefix_lanes=True)
        )(vol, nvm, ev, dm)
        oev[...], oidx[...] = ev, idx
        ook[...] = ok.astype(jnp.int32)
    elif phase == "deq":
        rem_ref, take_ref, at_ref, ah_ref = rest[:4]
        state_out, (oout, ocnt, oprb) = rest[4:21], rest[21:]
        # the work-stealing plan is cross-shard by nature: every program
        # reduces the full [Q, S] backlog snapshot (tiny) and slices out
        # its own shards' lane counts
        counts_all, probe = _plan_round(at_ref[...], ah_ref[...],
                                        rem_ref[0], take_ref[0], W)
        q0 = pl.program_id(0) * q_block
        counts = jax.lax.dynamic_slice(counts_all, (q0,), (q_block,))
        dmv = jnp.arange(W, dtype=jnp.int32)[None, :] < counts[:, None]
        ev = jnp.full((q_block, W), -1, jnp.int32)
        vol, nvm, _, outw = jax.vmap(
            lambda v, m, e, d: _wave_step(v, m, e, d, shard, b,
                                          do_enq=False, do_deq=True,
                                          prefix_lanes=True)
        )(vol, nvm, ev, dmv)
        oout[...], ocnt[...] = outw, counts
        oprb[...] = jnp.broadcast_to(probe.astype(jnp.int32), (q_block,))
    else:  # "wave"
        ev_ref, dm_ref = rest[0], rest[1]
        state_out, (oeok, odout) = rest[2:19], rest[19:]
        vol, nvm, eok, dout = jax.vmap(
            lambda v, m, e, d: _wave_step(v, m, e, d, shard, b)
        )(vol, nvm, ev_ref[...], dm_ref[...] != 0)
        oeok[...] = eok.astype(jnp.int32)
        odout[...] = dout
    _write_states(state_out, vol, nvm)


@functools.partial(jax.jit, static_argnames=("phase", "W", "interpret",
                                             "q_block"))
def fabric_fused_round(vol, nvm, shard, items=None, done=None,
                       remaining=None, take=None,
                       enq_vals=None, deq_mask=None,
                       *, phase: str, W: int, interpret: bool = True,
                       q_block: int | None = None):
    """One gridded driver round over the Q-stacked state.  Returns
    (vol', nvm') plus the per-phase extras documented on
    ``backend.PallasBackend.fused_fabric_round``."""
    Q, S, R = vol.vals.shape
    P = vol.mirrors.shape[1]
    if q_block is None:
        # one shard per grid program on parallel TPU cores; in interpret
        # mode the grid serializes on the host, so block the whole shard
        # axis into one program and let the in-body vmap vectorize it
        q_block = Q if interpret else 1
    if Q % q_block:
        raise ValueError(f"q_block {q_block} must divide Q {Q}")
    i32 = jnp.int32
    pool = pl.BlockSpec((q_block, S, R), lambda g: (g, 0, 0))
    row = pl.BlockSpec((q_block, S), lambda g: (g, 0))
    mir = pl.BlockSpec((q_block, P), lambda g: (g, 0))
    scal = pl.BlockSpec((q_block,), lambda g: (g,))
    wav = pl.BlockSpec((q_block, W), lambda g: (g, 0))
    smem = pl.BlockSpec(memory_space=pltpu.SMEM)

    state_in = [
        vol.vals, vol.idxs, vol.safes.astype(i32),
        vol.heads, vol.tails, vol.closed.astype(i32), vol.epoch, vol.base,
        vol.first, vol.last, vol.mirrors, vol.mirror_seg,
        nvm.vals, nvm.idxs, nvm.safes.astype(i32),
        nvm.mirrors, nvm.mirror_seg,
    ]
    state_specs = ([pool] * 3 + [row] * 5 + [scal] * 2 + [mir] * 2
                   + [pool] * 3 + [mir] * 2)
    state_shapes = (
        [jax.ShapeDtypeStruct((Q, S, R), i32)] * 3
        + [jax.ShapeDtypeStruct((Q, S), i32)] * 5
        + [jax.ShapeDtypeStruct((Q,), i32)] * 2
        + [jax.ShapeDtypeStruct((Q, P), i32)] * 2
        + [jax.ShapeDtypeStruct((Q, S, R), i32)] * 3
        + [jax.ShapeDtypeStruct((Q, P), i32)] * 2)

    w_shape = jax.ShapeDtypeStruct((Q, W), i32)
    q_shape = jax.ShapeDtypeStruct((Q,), i32)
    if phase == "enq":
        N = items.shape[1]
        seln = pl.BlockSpec((q_block, N), lambda g: (g, 0))
        extra_in = [jnp.asarray(items, i32), done.astype(i32)]
        extra_specs = [seln, seln]
        extra_out_specs = [wav, wav, wav]
        extra_out_shapes = [w_shape, w_shape, w_shape]
    elif phase == "deq":
        snap = pl.BlockSpec((Q, S), lambda g: (0, 0))
        extra_in = [jnp.asarray(remaining, i32).reshape(1),
                    jnp.asarray(take, i32).reshape(1),
                    vol.tails, vol.heads]
        extra_specs = [smem, smem, snap, snap]
        extra_out_specs = [wav, scal, scal]
        extra_out_shapes = [w_shape, q_shape, q_shape]
    elif phase == "wave":
        extra_in = [jnp.asarray(enq_vals, i32), deq_mask.astype(i32)]
        extra_specs = [wav, wav]
        extra_out_specs = [wav, wav]
        extra_out_shapes = [w_shape, w_shape]
    else:
        raise ValueError(f"unknown megakernel phase {phase!r}")

    outs = pl.pallas_call(
        functools.partial(_fabric_round_kernel, phase=phase, W=W,
                          q_block=q_block),
        grid=(Q // q_block,),
        in_specs=[smem] + state_specs + extra_specs,
        out_specs=state_specs + extra_out_specs,
        out_shape=state_shapes + extra_out_shapes,
        interpret=interpret,
    )(jnp.asarray(shard, i32).reshape(1), *state_in, *extra_in)

    s = outs[:17]
    vol2 = WaveState(
        vals=s[0], idxs=s[1], safes=s[2] != 0, heads=s[3], tails=s[4],
        closed=s[5] != 0, epoch=s[6], base=s[7], first=s[8], last=s[9],
        mirrors=s[10], mirror_seg=s[11])
    # nvm pass-through metadata (heads/tails/first/last) survives verbatim;
    # the segment-header line (closed/epoch/base) lands from the post-wave
    # vol image, exactly as _wave_step's fused write-back does
    nvm2 = nvm._replace(
        vals=s[12], idxs=s[13], safes=s[14] != 0,
        mirrors=s[15], mirror_seg=s[16],
        closed=vol2.closed, epoch=vol2.epoch, base=vol2.base)
    if phase == "enq":
        ev, idx, ok = outs[17:]
        return vol2, nvm2, ev, idx, ok != 0
    if phase == "deq":
        outw, counts, probe = outs[17:]
        return vol2, nvm2, outw, counts, probe[0] != 0
    eok, dout = outs[17:]
    return vol2, nvm2, eok != 0, dout
