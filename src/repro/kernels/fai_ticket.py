"""``fai_ticket`` -- batched Fetch&Increment as a blocked prefix-sum kernel.

The TPU-native replacement for the paper's FAI hot-spot: a wave of W
concurrent operations is assigned pairwise-distinct, gap-free tickets
``base + exclusive_cumsum(active)`` entirely in VMEM (no memory contention at
all -- the property FAI buys on x86, delivered by the VPU prefix network).

Grid iterates blocks sequentially (TPU grid order is sequential), carrying
the running count in SMEM scratch -- the standard blocked-scan pattern.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

DEFAULT_BLOCK = 1024


def _fai_ticket_kernel(base_ref, mask_ref, tickets_ref, newbase_ref, carry_ref):
    i = pl.program_id(0)
    nb = pl.num_programs(0)

    @pl.when(i == 0)
    def _init():
        carry_ref[0] = base_ref[0]

    m = mask_ref[...].astype(jnp.int32)
    ex = jnp.cumsum(m) - m
    tickets_ref[...] = carry_ref[0] + ex
    carry_ref[0] = carry_ref[0] + jnp.sum(m)

    @pl.when(i == nb - 1)
    def _fini():
        newbase_ref[0] = carry_ref[0]


@functools.partial(jax.jit, static_argnames=("block", "interpret"))
def fai_ticket(
    base: jnp.ndarray,
    mask: jnp.ndarray,
    *,
    block: int = DEFAULT_BLOCK,
    interpret: bool = True,
):
    """tickets[W], new_base = fai_ticket(base, mask[W]).

    Pads W up to a multiple of ``block``; the padding lanes are inactive so
    they do not affect the count."""
    W = mask.shape[0]
    blk = min(block, max(8, W))
    pad = (-W) % blk
    mask_p = jnp.pad(mask.astype(jnp.int32), (0, pad))
    n_blocks = mask_p.shape[0] // blk
    tickets_p, newbase = pl.pallas_call(
        _fai_ticket_kernel,
        grid=(n_blocks,),
        in_specs=[
            pl.BlockSpec(memory_space=pltpu.SMEM),     # base scalar
            pl.BlockSpec((blk,), lambda i: (i,)),      # mask block (VMEM)
        ],
        out_specs=[
            pl.BlockSpec((blk,), lambda i: (i,)),      # tickets block
            pl.BlockSpec(memory_space=pltpu.SMEM),     # new base scalar
        ],
        out_shape=[
            jax.ShapeDtypeStruct((mask_p.shape[0],), jnp.int32),
            jax.ShapeDtypeStruct((1,), jnp.int32),
        ],
        scratch_shapes=[pltpu.SMEM((1,), jnp.int32)],
        interpret=interpret,
    )(jnp.asarray(base, jnp.int32).reshape(1), mask_p)
    return tickets_p[:W], newbase[0]
