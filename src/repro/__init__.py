"""repro: fault-tolerant JAX training/serving framework built around the
persistent FIFO queues of Fatourou-Giachoudis-Mallis (2024)."""
__version__ = "0.1.0"
