from .queue_pipeline import PersistentDataPipeline  # noqa: F401
from .sources import synthetic_token_source  # noqa: F401
