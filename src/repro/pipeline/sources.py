"""Data sources for the pipeline (synthetic corpus for the examples/tests)."""
from __future__ import annotations

from typing import Iterator

import numpy as np


def synthetic_token_source(vocab: int, seq_len: int, seed: int = 0,
                           structured: bool = True) -> Iterator[np.ndarray]:
    """Infinite stream of token sequences.  ``structured`` makes them
    learnable (repeating n-gram patterns) so example training shows a real
    loss curve."""
    rng = np.random.default_rng(seed)
    sid = 0
    while True:
        if structured:
            period = int(rng.integers(3, 9))
            motif = rng.integers(0, vocab, period)
            reps = seq_len // period + 2
            seq = np.tile(motif, reps)[:seq_len + 1]
            noise = rng.random(seq_len + 1) < 0.05
            seq = np.where(noise, rng.integers(0, vocab, seq_len + 1), seq)
        else:
            seq = rng.integers(0, vocab, seq_len + 1)
        yield sid, seq.astype(np.int32)
        sid += 1
