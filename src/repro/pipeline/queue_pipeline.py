"""Persistent data pipeline: the paper's queue as the training input spine.

Producers (data workers) enqueue sample handles into a PerLCRQ-style wave
queue; the train loop dequeues batches.  Durable linearizability gives the
property large-scale training needs from its input pipeline: after a crash,
NO acknowledged sample is lost and NO sample is delivered twice
(exactly-once sample accounting), and recovery reconstructs the consumer
cursor from per-shard LOCAL mirrors (the paper's local-persistence technique)
instead of a checkpointed global counter.

The payloads live in a slab (sample store) keyed by the int32 handles that
flow through the queue; the slab is persisted by the same wave flush.
"""
from __future__ import annotations

from typing import Dict, Iterator, List, Optional

import jax.numpy as jnp
import numpy as np

from repro.api import Combiner, QueueConfig, Ticket, as_fault_plan
from repro.core.persistence import crash_recover_images


class PersistentDataPipeline:
    """Single-process reference implementation (the multi-host version runs
    one pipeline shard per data-parallel worker; shard id = mirror id).

    ``n_queues`` sharded queues carry the handles (MultiFIFO: per-queue FIFO,
    round-robin across queues -- sample order within a batch is already
    shuffled upstream, so the relaxation is free throughput)."""

    def __init__(self, source: Iterator, batch_size: int, seq_len: int,
                 slab_capacity: int = 4096, S: int = 32, R: int = 256,
                 W: int = 64, n_shards: int = 1, n_queues: int = 1,
                 backend: str = "jnp", driver: str = "device"):
        self.source = source
        self.batch_size = batch_size
        self.seq_len = seq_len
        # device-resident driving through the flat-combining front-end:
        # produce()/next_batch() cost one fused device call each, and
        # produce_async() lets many workers coalesce their trickle into
        # ONE maximal round at the next flush.  pipeline_depth=2: a
        # produce flush may stay in flight while the host stages the next
        # board; acknowledgement settles at the deferred sync.
        self.combiner = Combiner(config=QueueConfig(
            Q=n_queues, S=S, R=R, P=n_shards, W=W,
            backend=backend, driver=driver, detectable=True),
            pipeline_depth=2)
        self.queue = self.combiner.queue
        self.slab = np.zeros((slab_capacity, seq_len + 1), np.int32)
        self.slab_nvm = np.zeros_like(self.slab)
        self.slab_capacity = slab_capacity
        self._next_handle = 0
        self.produced = 0
        self.consumed = 0
        self.delivered_ids: List[int] = []
        # acknowledged (durably enqueued) handles: the exactly-once recovery
        # contract is defined over these.  Handles recycle mod slab_capacity;
        # when a slot is reused its previous incarnation's lifecycle is
        # FORGOTTEN (see produce), so recycled handles never alias in the
        # recovery accounting.  Producing over a handle still live in the
        # queue remains out of contract (the slab payload would be gone).
        self.acked: List[int] = []
        self._acked_set: set = set()
        self._stash: List[int] = []
        self._pending: List[Ticket] = []

    # -- producer side ---------------------------------------------------------

    def produce_async(self, n: int, shard: int = 0) -> Ticket:
        """Pull n samples from the source, persist payloads, ANNOUNCE the
        handles on the combiner board.  Returns the enqueue ticket; the
        handles become acknowledged (durably enqueued) at the next
        ``flush()``/``produce()``/``next_batch()``, when every worker's
        trickle coalesces into one maximal round."""
        handles = []
        for _ in range(n):
            sid, seq = next(self.source)
            h = self._next_handle % self.slab_capacity
            self._next_handle += 1
            if h in self._acked_set:
                # slot recycled: the previous incarnation's exactly-once
                # lifecycle is over -- forget it so handle reuse cannot
                # alias into the recovery accounting
                assert h not in self._stash, \
                    "slab overrun: recycling an undelivered handle"
                self.acked.remove(h)
                if h in self.delivered_ids:
                    self.delivered_ids.remove(h)
            self.slab[h] = seq
            self.slab_nvm[h] = seq  # payload persisted BEFORE the handle
            handles.append(h)
        t = self.combiner.submit_enqueue(handles, producer=shard)
        self._pending.append(t)
        return t

    def flush(self, shard: int = 0) -> None:
        """Run the combiner pass and settle every resolved produce ticket:
        completed handles become acknowledged; a per-ticket ``QueueFull``
        re-raises (its handles stay un-acked, exactly the pre-combiner
        failure surface).  At pipeline depth >= 2 the dispatched round may
        stay in flight: its tickets settle at the next deferred sync
        (``next_batch``'s ``result()``, ``produce``, or a later flush)."""
        self.combiner.flush(shard)
        self._settle()

    def _settle(self) -> None:
        err = None
        still: List[Ticket] = []
        for t in self._pending:
            if t.status == "pending":
                still.append(t)
            elif t.status == "done":
                self.acked.extend(t.items)
                self._acked_set.update(t.items)
                self.produced += len(t.items)
            elif t.status == "failed" and err is None:
                err = t._error
        self._pending = still
        if err is not None:
            raise err

    def produce(self, n: int, shard: int = 0) -> int:
        """Pull n samples from the source, persist payloads, enqueue handles
        (one combined round, together with any announced intents).
        Synchronous: retires the round before returning (the async path is
        ``produce_async``).  Returns the number acknowledged (durably
        enqueued)."""
        t = self.produce_async(n, shard)
        self.flush(shard)
        if t.status == "pending":
            try:
                t.result()          # deferred sync: retire the round now
            finally:
                self._settle()
        return len(t.items)

    # -- consumer side ---------------------------------------------------------

    def next_batch(self, shard: int = 0) -> Optional[Dict[str, jnp.ndarray]]:
        """Dequeue batch_size handles; returns a training batch or None if
        the queue ran dry (caller produces more / waits).  The demand rides
        one combined round with any announced produce intents."""
        ticket = self.combiner.submit_dequeue(self.batch_size,
                                              producer=shard)
        self.flush(shard)       # settles produce tickets too (acked)
        handles = ticket.result()   # deferred sync: retires the round
        self._settle()          # tickets resolved by that retirement
        if len(handles) < self.batch_size:
            # partial batch: push back is not allowed (queue semantics);
            # deliver only full batches in this reference impl, so requeue
            # remains impossible -- instead stash for the next call.
            self._stash = self._stash + handles
            if len(self._stash) < self.batch_size:
                return None
            handles, self._stash = (self._stash[: self.batch_size],
                                    self._stash[self.batch_size:])
        self.consumed += len(handles)
        self.delivered_ids.extend(handles)
        seqs = self.slab_nvm[np.asarray(handles, np.int64)]
        return {
            "tokens": jnp.asarray(seqs[:, :-1]),
            "labels": jnp.asarray(seqs[:, 1:]),
        }

    # -- fault tolerance ---------------------------------------------------------

    def crash_and_recover(self, torn: Optional[dict] = None,
                          seed: int = 0) -> None:
        """Full-system crash: volatile queue state lost; recovery per the
        paper (mirrors -> Head, array scan -> Tail).  ``torn`` (e.g.
        ``{"deq_lanes": 2}``) injects the crash MID-WAVE through the
        flush-delta injector instead of at a wave boundary.

        Exactly-once delivery: acknowledged samples whose dequeue transition
        persisted but that never reached the trainer (the stash, and torn
        mid-wave dequeues) are re-enqueued; samples still durably queued or
        already delivered are not.  The slab's volatile copy rebinds through
        ``crash_recover_images`` (the shared non-aliasing rule)."""
        self.combiner.crash(as_fault_plan(torn, seed=seed))
        # announced-but-unflushed produce tickets died with verdicts; their
        # handles were never acknowledged, so they are outside the
        # exactly-once contract (the producer re-submits on its ticket)
        self._pending = [t for t in self._pending if t.status == "pending"]
        survivors = set(self.queue.peek_items())
        delivered = set(self.delivered_ids)
        lost = [h for h in self.acked
                if h not in delivered and h not in survivors]
        self._stash = []
        if lost:
            self.combiner.submit_enqueue(lost).result()
        self.slab, self.slab_nvm = crash_recover_images(self.slab_nvm)

    def backlog(self) -> int:
        # durable queue items plus announced-but-unflushed produce intents
        return self.combiner.backlog()
