"""End-to-end LM training driver (deliverable b).

Default: a CPU-sized model for a quick demonstration of the full loop
(pipeline -> sharded step -> async checkpoints).  ``--preset 100m`` trains a
~100M-parameter internlm2-family model for a few hundred steps -- the
configuration used on real hardware.

Run:  PYTHONPATH=src python examples/train_lm.py [--preset 100m --steps 300]
"""
import argparse
import subprocess
import sys

ap = argparse.ArgumentParser()
ap.add_argument("--preset", choices=["tiny", "100m"], default="tiny")
ap.add_argument("--steps", type=int, default=None)
args = ap.parse_args()

if args.preset == "tiny":
    steps = args.steps or 60
    cmd = ["--reduced", "--width", "256", "--layers", "4",
           "--batch", "8", "--seq", "128", "--steps", str(steps)]
else:
    # ~100M params: d=768, 12 layers, ff=3072, vocab 32k (reduced vocab)
    steps = args.steps or 300
    cmd = ["--reduced", "--width", "768", "--layers", "12",
           "--batch", "8", "--seq", "512", "--steps", str(steps)]

p = subprocess.run(
    [sys.executable, "-m", "repro.launch.train", "--arch", "internlm2-1.8b",
     "--ckpt", "/tmp/repro_train_lm_ckpt", *cmd],
    env={"PYTHONPATH": "src"}, cwd=".")
sys.exit(p.returncode)
