"""Flat-combining async front-end demo (DESIGN.md §9): N producers announce
small enqueue/dequeue intents, one combiner flushes them as maximal device
waves, then a torn crash lands MID-ROUND and every in-flight ticket gets a
definitive completed/not-completed verdict (detectable recovery).

Run:  PYTHONPATH=src python examples/async_producers_demo.py
"""
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__) or ".", "..",
                                "src"))
from repro.api import QueueConfig, open_combiner             # noqa: E402

N_PRODUCERS = 6
BATCH = 4                      # tiny per-producer batches: the combining case
Q, W = 4, 8

print(f"=== phase 1: {N_PRODUCERS} producers x batch {BATCH}, one combined "
      "round ===")
c = open_combiner(QueueConfig(Q=Q, S=4, R=64, W=W))
print("capabilities.detectable_recovery =",
      c.queue.capabilities.detectable_recovery)
tickets = [c.submit_enqueue([p * 100 + j for j in range(BATCH)], producer=p)
           for p in range(N_PRODUCERS)]
consumer = c.submit_dequeue(BATCH, producer=99)
print(f"board: {c.pending()} tickets pending "
      f"({c.pending_enqueue_items()} items announced, queue still empty: "
      f"backlog {c.queue.backlog()})")
c.flush()
for t in tickets:
    assert t.done() and t.result() == list(t.items)
print(f"flushed as one round: consumer got {consumer.result()}")
st = c.persist_stats()
print(f"persist economy: {st['ops_total']} ops, "
      f"{st['psyncs_total_with_journal']} psyncs (journal included), "
      f"wave occupancy {c.wave_occupancy():.3f}")

print("\n=== phase 2: mid-run TORN crash, per-ticket verdicts ===")
inflight = [c.submit_enqueue([1000 + p * 10 + j for j in range(BATCH)],
                             producer=p) for p in range(N_PRODUCERS)]
inflight.append(c.submit_enqueue(list(range(2000, 2000 + Q * W))))  # overflow
refill = c.submit_dequeue(3, producer=99)
verdicts = c.crash_torn(seed=7)
print(f"{len(verdicts)} outstanding tickets resolved:")
for t in inflight + [refill]:
    v = t.verdict
    print(f"  ticket {v.ticket:>2} producer {v.producer:>2} {v.kind}: "
          f"completed={str(v.completed):<5} note={v.note}"
          + (f" survived={len(v.survived)}/{len(t.items)}"
             if v.kind == "enq" else ""))
assert all(t.verdict is not None for t in inflight)
assert not refill.verdict.completed    # a dead response is never 'completed'

print("\n=== phase 3: verdicts are CORRECT -- sweep every crash point "
      "through check_wave_crash ===")
for p in range(N_PRODUCERS):
    c.submit_enqueue([3000 + p * 10 + j for j in range(BATCH)], producer=p)
c.submit_dequeue(2)
sweep = c.crash_sweep(n_points=128, seed=11)
agg = sweep.check()            # queue-level durable linearizability + verdicts
print(f"128-point sweep: {agg['verdicts']} verdicts validated, "
      f"{agg['completed_tickets']} completed across points; "
      f"check_wave_crash aggregate {dict(list(agg.items())[:2])}")
print("\n=== phase 4: overlapped flush pipeline (depth 2, DESIGN.md §10) ===")
cp = open_combiner(QueueConfig(Q=Q, S=4, R=64, W=W), pipeline_depth=2)
d0, s0 = cp.queue.dispatches, cp.queue.host_syncs
deq_tickets = []
for f in range(4):             # consecutive flushes: each returns with the
    for p in range(N_PRODUCERS):   # fused round still in flight
        cp.submit_enqueue([5000 + f * 100 + p * 10 + j for j in range(BATCH)],
                          producer=p)
    deq_tickets.append(cp.submit_dequeue(N_PRODUCERS * BATCH, producer=99))
    cp.flush()
    print(f"flush {f}: returned with {cp.in_flight()} round in flight "
          f"(tickets {'pending' if deq_tickets[-1].status == 'pending' else 'resolved'})")
cp.settle()                    # the deferred sync of the tail flight
got = sum(len(t.result()) for t in deq_tickets)
d, s = cp.queue.dispatches - d0, cp.queue.host_syncs - s0
print(f"4 flushes, {got} items delivered: {d} device dispatches "
      f"({d / 4:.0f} per flush -- ONE fused submit_round each), "
      f"{s} blocking host syncs (deferred to retirement)")
assert d == 4 and cp.backlog() == 0

print("\nasync producers demo complete: intents coalesced into maximal "
      "waves dispatched as single fused rounds, flushes pipelined past the "
      "host sync, every in-flight ticket crash-resolved with a correct "
      "verdict.")
