"""Continuous-batching serving behind the persistent request queue, with a
mid-serving crash: no request is lost, none is answered twice.

Run:  PYTHONPATH=src python examples/serve_continuous_batching.py
"""
import subprocess
import sys

p = subprocess.run(
    [sys.executable, "-m", "repro.launch.serve", "--arch", "gemma3-1b",
     "--requests", "10", "--max-new", "6", "--max-batch", "3",
     "--crash-after", "4"],
    env={"PYTHONPATH": "src"}, cwd=".")
assert p.returncode == 0
