"""Quickstart: the persistent queue through the ONE public handle.

1. The faithful PerLCRQ on the simulated NVM machine (paper Algorithm 3/5),
   with a crash + recovery.
2. `repro.api.open_queue`: a strict-FIFO handle (Q=1), batched waves,
   a clean crash and a drain.
3. The same handle as a Q=4 fabric -- same API, negotiated capabilities,
   one vectorized fabric-wide recovery -- plus a torn mid-wave crash
   through the unified FaultPlan surface.
4. Maintenance: the quiescent int32 ticket rebase (DESIGN.md §8).

Run:  PYTHONPATH=src python examples/quickstart.py

The durability/dispatch invariants this file leans on (persist-before-sync,
<=2 persistence instructions/op, np.int32 dispatch discipline) are checked
statically by  PYTHONPATH=src python -m repro.analysis.qlint src
(DESIGN.md §11).
"""
from repro.api import FaultPlan, QueueConfig, open_queue
from repro.core.harness import drain, pairs_workload, random_schedule, run_epoch
from repro.core.lcrq import LCRQ, install_line_map
from repro.core.machine import Machine

# --- 1. faithful PerLCRQ with a crash ---------------------------------------
m = Machine(4, eviction_rate=0.01, seed=7)
install_line_map(m)
q = LCRQ(m, R=8, mode="percrq")
history = run_epoch(m, q, pairs_workload(4, 30), random_schedule(4, 400_000, 7),
                    crash_at_step=1500)
m.restart()
stats = q.recover()
left = drain(m, q)
done = sum(1 for r in history if r.completed)
print(f"[PerLCRQ/sim] {done} ops completed before the crash; recovery walked "
      f"{stats['nodes']} CRQ nodes; {len(left)} items recovered in FIFO order")
print(f"[PerLCRQ/sim] pwbs={m.persist_count} psyncs={m.psync_count} "
      f"(~1 pair per completed op -- the paper's optimal)")

# --- 2. one handle, strict FIFO (Q=1) ----------------------------------------
wq = open_queue(QueueConfig(Q=1, S=8, R=64, W=16))
assert wq.capabilities.ordering == "strict_fifo"
wq.enqueue_all(range(40))
got, _ = wq.dequeue_n(10)
wq.crash(FaultPlan("clean"))
rest = wq.drain()
print(f"[api/Q=1] dequeued {got[:5]}... then crashed; recovered {len(rest)} "
      f"items, order intact: {rest[:5]}...")
assert got == list(range(10)) and rest == list(range(10, 40))

# --- 3. same handle as a Q=4 fabric + a torn mid-wave crash ------------------
fab = open_queue(QueueConfig(Q=4, S=8, R=64, W=16, backend="jnp"))
caps = fab.capabilities
print(f"[api/Q=4] negotiated: ordering={caps.ordering} "
      f"rank_error<={caps.rank_error} capacity~{caps.capacity_hint}")
fab.enqueue_all(range(80))                # round-robin across 4 queues
got = fab.dequeue_n(20)[0]
fab.crash(FaultPlan("torn", enq_items=(500, 501), deq_lanes=2, seed=3))
rest = fab.drain()
stats = fab.persist_stats()
delivered = got + rest
assert len(delivered) == len(set(delivered)), "duplicate across torn crash"
# losses are bounded by the crashed wave's in-flight dequeues (2 lanes x 4
# queues); the two in-flight enqueues may or may not have linearized
lost = set(range(80)) - set(delivered)
assert len(lost) <= 2 * 4, lost
print(f"[api/Q=4] {len(got)} dequeued, torn mid-wave crash, {len(rest)} "
      f"recovered; pwbs/op={stats['pwbs_total'] / max(stats['ops_total'], 1):.2f} "
      f"(pair-per-op discipline per shard)")

# --- 4. maintenance: the quiescent ticket rebase -----------------------------
churn = open_queue(QueueConfig(Q=2, S=2, R=32, W=16))
n = 0
for _ in range(4):                        # recycle segments, grow the bases
    churn.enqueue_all(range(n, n + 128))
    n += 128
    churn.drain()
mnt = churn.maintenance()
before = mnt.ticket_headroom()
report = mnt.rebase()                     # drained => quiescent => rebase
churn.enqueue_all(range(10))
assert sorted(churn.drain()) == list(range(10))
print(f"[maintenance] rebase reclaimed base<={report.headroom_reclaimed} "
      f"per row (headroom {before} -> {mnt.ticket_headroom()}); "
      f"queue fully functional after")
print("quickstart complete.")
