"""Quickstart: the persistent queue four ways.

1. The faithful PerLCRQ on the simulated NVM machine (paper Algorithm 3/5),
   with a crash + recovery.
2. The TPU-native wave engine (JAX) -- same semantics, batched.
3. The Pallas kernels validating against their oracles.
4. The sharded queue fabric: Q wave queues behind one endpoint, with a
   fabric-wide crash + one vectorized recovery.

Run:  PYTHONPATH=src python examples/quickstart.py
"""
import random

import jax.numpy as jnp

from repro.core.fabric import ShardedWaveQueue
from repro.core.harness import drain, pairs_workload, random_schedule, run_epoch
from repro.core.lcrq import LCRQ, install_line_map
from repro.core.machine import Machine
from repro.core.wave import WaveQueue
from repro.kernels import ops, ref

# --- 1. faithful PerLCRQ with a crash ---------------------------------------
m = Machine(4, eviction_rate=0.01, seed=7)
install_line_map(m)
q = LCRQ(m, R=8, mode="percrq")
history = run_epoch(m, q, pairs_workload(4, 30), random_schedule(4, 400_000, 7),
                    crash_at_step=1500)
m.restart()
stats = q.recover()
left = drain(m, q)
done = sum(1 for r in history if r.completed)
print(f"[PerLCRQ/sim] {done} ops completed before the crash; recovery walked "
      f"{stats['nodes']} CRQ nodes; {len(left)} items recovered in FIFO order")
print(f"[PerLCRQ/sim] pwbs={m.persist_count} psyncs={m.psync_count} "
      f"(~1 pair per completed op -- the paper's optimal)")

# --- 2. wave engine ----------------------------------------------------------
wq = WaveQueue(S=8, R=64, W=16)
wq.enqueue_all(list(range(40)))
got, _ = wq.dequeue_n(10)
wq.crash_and_recover()
rest = wq.drain()
print(f"[wave] dequeued {got[:5]}... then crashed; recovered {len(rest)} items,"
      f" order intact: {rest[:5]}...")
assert got == list(range(10)) and rest == list(range(10, 40))

# --- 3. kernels vs oracles ----------------------------------------------------
mask = jnp.array([1, 0, 1, 1, 0, 1, 1, 0], bool)
tk, nb = ops.fai_ticket(jnp.int32(100), mask)
tr, nr = ref.fai_ticket(jnp.int32(100), mask)
assert (tk == tr).all() and nb == nr
print(f"[kernels] fai_ticket OK: tickets={list(map(int, tk))} (base 100)")

# --- 4. sharded fabric --------------------------------------------------------
fab = ShardedWaveQueue(Q=4, S=8, R=64, W=16)
fab.enqueue_all(list(range(80)))          # round-robin across 4 shards
got = fab.dequeue_n(20)[0]
fab.crash_and_recover()                   # one vectorized scan, all shards
rest = fab.drain()
stats = fab.persist_stats()
assert sorted(got + rest) == list(range(80))
print(f"[fabric] Q=4 shards: {len(got)} dequeued, crashed, {len(rest)} "
      f"recovered; pwbs/op={stats['pwbs'].sum() / stats['ops'].sum():.2f} "
      f"(pair-per-op discipline per shard)")
print("quickstart complete.")
