"""End-to-end crash/recovery demo: train with checkpoints + persistent data
pipeline, kill the run mid-flight, restart, verify exactly-once sample
delivery and step recovery from worker mirrors -- then sweep a fabric wave
through hundreds of TORN crash points (crashes that land between the pwbs
of one flush) and hold every recovery to durable linearizability.

Run:  PYTHONPATH=src python examples/crash_recovery_demo.py
"""
import shutil
import subprocess
import sys

CKPT = "/tmp/repro_demo_ckpt"
shutil.rmtree(CKPT, ignore_errors=True)

base = [sys.executable, "-m", "repro.launch.train", "--arch", "internlm2-1.8b",
        "--reduced", "--steps", "60", "--batch", "4", "--seq", "64",
        "--ckpt", CKPT, "--ckpt-every", "10", "--log-every", "10"]

print("=== phase 1: run until simulated crash at step 35 ===")
p = subprocess.run(base + ["--crash-at", "35"], env={"PYTHONPATH": "src"},
                   cwd=".")
assert p.returncode == 42, f"expected simulated-crash exit 42, got {p.returncode}"

print("\n=== phase 2: restart -- recovery resumes from the mirror max ===")
p = subprocess.run(base, env={"PYTHONPATH": "src"}, cwd=".")
assert p.returncode == 0
print("\ncrash/recovery demo complete: training resumed from the last "
      "durable checkpoint (max over per-worker step mirrors).")

print("\n=== phase 3: fabric torn-crash sweep (DESIGN.md §7) ===")
import os                                                    # noqa: E402
sys.path.insert(0, os.path.join(os.path.dirname(__file__) or ".", "..",
                                "src"))
import jax                                                   # noqa: E402
import jax.numpy as jnp                                      # noqa: E402

from repro.core.consistency import check_wave_crash          # noqa: E402
from repro.core.fabric import (ShardedWaveQueue,             # noqa: E402
                               fabric_crash_sweep, fabric_step_delta)
from repro.core.persistence import tree_copy                 # noqa: E402
from repro.core.wave import peek_items                       # noqa: E402

N_POINTS = 256
Q, W = 2, 8
f = ShardedWaveQueue(Q=Q, S=4, R=32, W=W)
f.enqueue_all(list(range(100, 140)))
f.dequeue_n(6)
pre_q = f.peek_items_per_queue()
nvm_pre = tree_copy(f.nvm)

# one in-flight wave: 4 enqueues (round-robin placed) + 3 dequeue lanes/queue
wave_items = list(range(500, 504))
ev, dm, per_q = f.plan_torn_wave(wave_items, 3)
_, _, _, _, delta = fabric_step_delta(
    f.vol, f.nvm, jnp.asarray(ev), jnp.asarray(dm), jnp.int32(0))

# materialize + recover N_POINTS torn images in ONE vmapped device call
rec, _ = fabric_crash_sweep(nvm_pre, delta, jax.random.PRNGKey(0), N_POINTS)
rec = jax.device_get(rec)
lost = survived = 0
for i in range(N_POINTS):
    for q in range(Q):
        out = peek_items(jax.tree.map(lambda a: a[i][q], rec))
        r = check_wave_crash(pre_q[q], per_q[q], 3, out)
        lost += r["lost_prefix"]
        survived += r["survived_wave_enqs"]
print(f"{N_POINTS} torn crash points x {Q} shards recovered; every one "
      f"durably linearizable")
print(f"  in-flight dequeues that had linearized: {lost} cells; in-flight "
      f"enqueues that survived: {survived}")
