"""End-to-end crash/recovery demo: train with checkpoints + persistent data
pipeline, kill the run mid-flight, restart, verify exactly-once sample
delivery and step recovery from worker mirrors.

Run:  PYTHONPATH=src python examples/crash_recovery_demo.py
"""
import shutil
import subprocess
import sys

CKPT = "/tmp/repro_demo_ckpt"
shutil.rmtree(CKPT, ignore_errors=True)

base = [sys.executable, "-m", "repro.launch.train", "--arch", "internlm2-1.8b",
        "--reduced", "--steps", "60", "--batch", "4", "--seq", "64",
        "--ckpt", CKPT, "--ckpt-every", "10", "--log-every", "10"]

print("=== phase 1: run until simulated crash at step 35 ===")
p = subprocess.run(base + ["--crash-at", "35"], env={"PYTHONPATH": "src"},
                   cwd=".")
assert p.returncode == 42, f"expected simulated-crash exit 42, got {p.returncode}"

print("\n=== phase 2: restart -- recovery resumes from the mirror max ===")
p = subprocess.run(base, env={"PYTHONPATH": "src"}, cwd=".")
assert p.returncode == 0
print("\ncrash/recovery demo complete: training resumed from the last "
      "durable checkpoint (max over per-worker step mirrors).")
