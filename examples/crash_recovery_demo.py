"""End-to-end crash/recovery demo: train with checkpoints + persistent data
pipeline, kill the run mid-flight, restart, verify exactly-once sample
delivery and step recovery from worker mirrors -- then sweep a fabric wave
through hundreds of TORN crash points (crashes that land between the pwbs
of one flush) and hold every recovery to durable linearizability.

Run:  PYTHONPATH=src python examples/crash_recovery_demo.py
"""
import shutil
import subprocess
import sys

CKPT = "/tmp/repro_demo_ckpt"
shutil.rmtree(CKPT, ignore_errors=True)

base = [sys.executable, "-m", "repro.launch.train", "--arch", "internlm2-1.8b",
        "--reduced", "--steps", "60", "--batch", "4", "--seq", "64",
        "--ckpt", CKPT, "--ckpt-every", "10", "--log-every", "10"]

print("=== phase 1: run until simulated crash at step 35 ===")
p = subprocess.run(base + ["--crash-at", "35"], env={"PYTHONPATH": "src"},
                   cwd=".")
assert p.returncode == 42, f"expected simulated-crash exit 42, got {p.returncode}"

print("\n=== phase 2: restart -- recovery resumes from the mirror max ===")
p = subprocess.run(base, env={"PYTHONPATH": "src"}, cwd=".")
assert p.returncode == 0
print("\ncrash/recovery demo complete: training resumed from the last "
      "durable checkpoint (max over per-worker step mirrors).")

print("\n=== phase 3: fabric torn-crash sweep (DESIGN.md §7/§8) ===")
import os                                                    # noqa: E402
sys.path.insert(0, os.path.join(os.path.dirname(__file__) or ".", "..",
                                "src"))
from repro.api import FaultPlan, QueueConfig, open_queue     # noqa: E402

N_POINTS = 256
Q = 2
f = open_queue(QueueConfig(Q=Q, S=4, R=32, W=8))
f.enqueue_all(list(range(100, 140)))
f.dequeue_n(6)

# one in-flight wave (4 round-robin enqueues + 3 dequeue lanes/queue),
# swept over N_POINTS torn crash points in ONE vmapped device call; the
# SweepResult feeds every recovery through the shared checker
sweep = f.crash(FaultPlan("sweep", enq_items=range(500, 504), deq_lanes=3,
                          n_points=N_POINTS))
r = sweep.check()
print(f"{N_POINTS} torn crash points x {Q} shards recovered; every one "
      f"durably linearizable")
print(f"  in-flight dequeues that had linearized: {r['lost_prefix']} cells; "
      f"in-flight enqueues that survived: {r['survived_wave_enqs']}")

print("\n=== phase 4: quiescent ticket rebase survives torn crashes ===")
f.drain()                                 # quiesce: maintenance needs empty
for i in range(3):                        # churn: recycle rows, grow bases
    f.enqueue_all(range(1000 + 256 * i, 1000 + 256 * (i + 1)))
    f.drain()
rec = f.maintenance().rebase_sweep(n_points=128, seed=1)
import jax                                                   # noqa: E402
from repro.core.wave import peek_items                       # noqa: E402
rec = jax.device_get(rec)
assert all(not peek_items(jax.tree.map(lambda a: a[i][q], rec))
           for i in range(128) for q in range(Q))
report = f.maintenance().rebase()
print(f"128 mid-rebase crash points x {Q} shards all recovered EMPTY; "
      f"completed rebase reset bases {report.max_base_before} -> 0")
